"""N-level reduction hierarchy: the `ReductionPlan`.

The paper's Algorithm 1 is the 2-level special case (cluster-local every K1
steps, global every K2) of a general hierarchy: an ordered list of
:class:`ReductionLevel` entries, each naming a scope (which stacked learner
axes it averages over), a period (how many SGD steps between its
reductions), and a reducer (what each learner puts on the wire at that
level — see comm/).  A 3-level ICI/DCI-aligned plan looks like

    local@4:cast:bfloat16 / pod@8:mean / global@16:topk:0.05

i.e. average within each S-learner cluster every 4 steps with a bf16
payload, across each pod every 8, and across all P learners every 16 with
a 5%-topk payload.  Nesting is validated: each level's axes must contain
the previous level's, and each period must divide the next.

``ReductionPlan.from_k1_k2(k1, k2, reducer)`` builds the paper's 2-level
plan; ``HierAvgParams`` uses it so legacy ``(k1, k2, reducer)`` configs run
bit-identically through the plan machinery.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Tuple, Union

from repro.comm import (DEFAULT_BUCKET_BYTES, Bucketed, Pipelined, Reducer,
                        get_reducer)
from repro.core.topology import (GLOBAL_ARRAY_AXES, LOCAL_ARRAY_AXES,
                                 POD_ARRAY_AXES)

# level name -> stacked array axes the reduction averages over
LEVEL_AXES = {
    "local": LOCAL_ARRAY_AXES,     # within each cluster of S learners
    "pod": POD_ARRAY_AXES,         # all learners of one pod (ICI boundary)
    "global": GLOBAL_ARRAY_AXES,   # all P learners (crosses DCI)
}


@dataclass(frozen=True, eq=False)
class ReductionLevel:
    """One rung of the hierarchy.

    ``axes`` are stacked-learner array axes (core/topology.py);
    ``period`` is in SGD steps; ``reducer`` is a comm/ Reducer instance.
    """

    name: str
    axes: Tuple[int, ...]
    period: int
    reducer: Reducer

    def describe(self) -> str:
        return f"{self.name}@{self.period}:{self.reducer.describe()}"

    def __repr__(self) -> str:
        return f"ReductionLevel({self.describe()})"


PlanLike = Union["ReductionPlan", str, None]


@dataclass(frozen=True, eq=False)
class ReductionPlan:
    """Ordered (innermost -> outermost) reduction levels.

    Invariants enforced at construction:
      * at least one level, unique known names (local / pod / global);
      * scopes nest: level i's axes are a superset of level i-1's;
      * periods nest: each level's period divides the next level's.
    """

    levels: Tuple[ReductionLevel, ...]

    def __post_init__(self):
        if not self.levels:
            raise ValueError("a ReductionPlan needs at least one level")
        names = [lvl.name for lvl in self.levels]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate level names in plan: {names}")
        for lvl in self.levels:
            if lvl.name not in LEVEL_AXES:
                raise ValueError(
                    f"unknown level name {lvl.name!r}; "
                    f"known: {sorted(LEVEL_AXES)}")
            if lvl.period < 1:
                raise ValueError(
                    f"level {lvl.name!r} period must be >= 1, "
                    f"got {lvl.period}")
        for lo, hi in zip(self.levels, self.levels[1:]):
            if not set(hi.axes) >= set(lo.axes):
                raise ValueError(
                    f"level {hi.name!r} axes {hi.axes} must contain "
                    f"inner level {lo.name!r} axes {lo.axes}")
            if hi.period % lo.period != 0:
                raise ValueError(
                    f"level {lo.name!r} period {lo.period} must divide "
                    f"level {hi.name!r} period {hi.period}")

    # ------------------------------------------------------------------ #
    # construction
    # ------------------------------------------------------------------ #

    @classmethod
    def parse(cls, spec: str) -> "ReductionPlan":
        """``"name@period[:reducer_spec]"`` entries joined by ``/``, e.g.
        ``"local@4:cast:bfloat16/pod@8/global@16:topk:0.05"`` (reducer
        defaults to ``mean``)."""
        levels = []
        for part in str(spec).split("/"):
            part = part.strip()
            if "@" not in part:
                raise ValueError(
                    f"bad plan entry {part!r}: expected name@period"
                    f"[:reducer_spec]")
            name, _, rest = part.partition("@")
            period_s, _, red_spec = rest.partition(":")
            try:
                period = int(period_s)
            except ValueError:
                raise ValueError(
                    f"bad period {period_s!r} in plan entry {part!r}")
            name = name.strip()
            axes = LEVEL_AXES.get(name)
            if axes is None:
                raise ValueError(
                    f"unknown level name {name!r} in plan entry {part!r}; "
                    f"known: {sorted(LEVEL_AXES)}")
            levels.append(ReductionLevel(
                name=name, axes=axes, period=period,
                reducer=get_reducer(red_spec or "mean")))
        return cls(tuple(levels))

    @classmethod
    def from_k1_k2(cls, k1: int, k2: int, reducer="mean") -> "ReductionPlan":
        """The paper's 2-level hierarchy (Algorithm 1): cluster-local every
        K1 steps, global every K2, one reducer for both."""
        red = get_reducer(reducer)
        return cls((
            ReductionLevel("local", LEVEL_AXES["local"], k1, red),
            ReductionLevel("global", LEVEL_AXES["global"], k2, red),
        ))

    # ------------------------------------------------------------------ #
    # derived shape / schedule facts
    # ------------------------------------------------------------------ #

    @property
    def total_period(self) -> int:
        """SGD steps per round (the outermost level's period)."""
        return self.levels[-1].period

    @property
    def batch_dims(self) -> Tuple[int, ...]:
        """Leading round-batch dims, outermost ratio first:
        (p_N/p_{N-1}, ..., p_2/p_1, p_1).  2-level == (beta, K1)."""
        dims = [self.levels[0].period]
        for lo, hi in zip(self.levels, self.levels[1:]):
            dims.append(hi.period // lo.period)
        return tuple(reversed(dims))

    def counts_per_round(self) -> Tuple[Tuple[str, int], ...]:
        """(name, billable reductions per round) per level.

        A reduction coinciding with an outer level's is NOT counted: for
        dense means the outer average makes it a numeric no-op, so a
        payload-aware schedule would skip it — the same convention as
        ``theory.comm_per_k2_steps``.  Note the scan-nest round program
        still *executes* inner reductions at outer boundaries (and for
        error-feedback reducers those do update per-level EF state);
        this method models the wire bill, not the op count.
        """
        N = self.total_period
        out = []
        for i, lvl in enumerate(self.levels):
            n = N // lvl.period
            if i + 1 < len(self.levels):
                n -= N // self.levels[i + 1].period
            out.append((lvl.name, n))
        return tuple(out)

    # ------------------------------------------------------------------ #
    # derivation
    # ------------------------------------------------------------------ #

    def with_outer_period(self, period: int) -> "ReductionPlan":
        """Same plan with the outermost period replaced (inner levels
        fixed) — the AdaptivePlan knob."""
        outer = replace(self.levels[-1], period=period)
        return ReductionPlan(self.levels[:-1] + (outer,))

    def with_periods(self, periods) -> "ReductionPlan":
        """Same levels/reducers with EVERY period replaced (innermost
        first) — the CostAwarePlan knob (autotune/controller.py).
        Nesting (each period divides the next) is re-validated by the
        constructor."""
        periods = tuple(int(p) for p in periods)
        if len(periods) != len(self.levels):
            raise ValueError(
                f"need {len(self.levels)} periods (one per level), "
                f"got {periods}")
        return ReductionPlan(tuple(
            replace(lvl, period=p)
            for lvl, p in zip(self.levels, periods)))

    def with_reducer(self, reducer) -> "ReductionPlan":
        """Same schedule with every level's reducer replaced (the legacy
        single-``reducer`` override)."""
        red = get_reducer(reducer)
        return ReductionPlan(tuple(replace(lvl, reducer=red)
                                   for lvl in self.levels))

    def describe(self) -> str:
        return "/".join(lvl.describe() for lvl in self.levels)

    def __repr__(self) -> str:
        return f"ReductionPlan({self.describe()})"


def apply_bucketing(plan: ReductionPlan, bucket_bytes: int,
                    overlap: bool = True, shards=None) -> ReductionPlan:
    """Wrap each level's reducer in a bucket engine (comm/bucket.py) so
    it compresses and all-reduces size-capped flat buckets instead of
    raw leaves — :class:`~repro.comm.Pipelined` (the double-buffered
    overlapped schedule) when ``overlap`` is on, plain
    :class:`~repro.comm.Bucketed` (strictly serial) otherwise.

    Applied per level: reducers opted out (``:perleaf``) stay per-leaf,
    ``bucket_by_default`` codecs (cast / topk / randk / qint8) are
    wrapped automatically, and reducers already wrapped (the
    ``:bucketed`` spec modifier) keep their wrapper but inherit this
    ``bucket_bytes`` cap unless they were built with an explicit one —
    so the config knob governs explicit markers too.  The dense mean and
    PowerSGD keep per-leaf semantics unless explicitly marked.
    ``bucket_bytes <= 0`` disables auto-wrapping (explicit ``:bucketed``
    markers still apply, at their own/default cap).

    Schedule selection honors the spec modifiers over the knob: an
    explicit ``:pipelined`` reducer stays pipelined even with
    ``overlap=False``, and a ``:serial`` pin stays serial even with
    ``overlap=True``.  (Pipelined layouts with a single bucket fall back
    to the serial schedule at trace time — same math, nothing to
    overlap — so the default path is unchanged for small models.)

    ``shards`` (a :class:`~repro.parallel.sharding.ShardPlan` from an
    ``fsdp > 1`` layout, or None) is threaded into every bucket engine so
    layouts pack per-shard runs and the grouped means lower to
    reduce-scatter + all-gather; wrappers already carrying a different
    ShardPlan are rebuilt.
    """
    levels, changed = [], False
    for lvl in plan.levels:
        r = lvl.reducer
        new = r
        if isinstance(r, Bucketed):
            if isinstance(r, Pipelined) and r.pipeline_pin:
                engine = Pipelined           # explicit :pipelined wins
            elif r.overlap_opt_out or r.inner.overlap_opt_out:
                engine = Bucketed            # explicit :serial pin
            else:
                # auto-chosen wrappers (including Pipelined ones a
                # previous resolution created) follow the current knob —
                # so re-resolving a default plan with overlap=False
                # really demotes it to the serial engine
                engine = Pipelined if overlap else Bucketed
            cap = r.bucket_bytes
            if (cap is None and bucket_bytes and bucket_bytes > 0
                    and bucket_bytes != r.effective_bucket_bytes):
                cap = bucket_bytes
            want_shards = shards if shards is not None else r.shards
            if (type(r) is not engine or cap != r.bucket_bytes
                    or want_shards is not r.shards):
                new = engine(r.inner, cap, shards=want_shards)
                new.overlap_opt_out = r.overlap_opt_out
                new.pipeline_pin = getattr(r, "pipeline_pin", False)
        elif (bucket_bytes and bucket_bytes > 0
                and r.bucket_by_default and not r.bucket_opt_out):
            engine = Pipelined if (overlap and not r.overlap_opt_out) \
                else Bucketed
            new = engine(r, bucket_bytes, shards=shards)  # ':serial' pin
            # stays visible via new.inner.overlap_opt_out (describe
            # round-trip)
        if new is not r:
            lvl = replace(lvl, reducer=new)
            changed = True
        levels.append(lvl)
    return ReductionPlan(tuple(levels)) if changed else plan


def apply_shards(plan: ReductionPlan, shards) -> ReductionPlan:
    """Thread a :class:`~repro.parallel.sharding.ShardPlan` into an
    already-resolved plan's bucket engines, keeping each level's engine
    choice and cap — for callers that hold a ``ReductionPlan`` instance
    (init_state / make_hier_round with ``plan=...``) and only need the
    fsdp layout attached.  ``shards=None`` is a no-op."""
    if shards is None:
        return plan
    levels, changed = [], False
    for lvl in plan.levels:
        r = lvl.reducer
        if isinstance(r, Bucketed) and r.shards is not shards:
            new = type(r)(r.inner, r.bucket_bytes, shards=shards)
            new.overlap_opt_out = r.overlap_opt_out
            new.pipeline_pin = getattr(r, "pipeline_pin", False)
            lvl = replace(lvl, reducer=new)
            changed = True
        levels.append(lvl)
    return ReductionPlan(tuple(levels)) if changed else plan


def resolve_plan(hier, reducer=None, plan: PlanLike = None,
                 shards=None) -> ReductionPlan:
    """The plan a round/step builder actually uses.

    Precedence: explicit ``plan`` argument (instance or spec string), then
    ``hier.plan``, then the legacy 2-level plan from ``hier.k1``/``hier.k2``.
    An explicit ``reducer`` (spec or instance) overrides the reducer of
    EVERY level — the legacy single-reducer behavior.  Finally
    ``hier.bucket_bytes`` buckets compressed levels (:func:`apply_bucketing`)
    — on the pipelined schedule unless ``hier.overlap`` is off — so round
    builders, state init, and payload accounting all agree on the packed
    layout.
    """
    if plan is None:
        plan = getattr(hier, "plan", None)
    if plan is None:
        p = ReductionPlan.from_k1_k2(
            hier.k1, hier.k2, getattr(hier, "reducer", "mean"))
    elif isinstance(plan, ReductionPlan):
        p = plan
    else:
        p = ReductionPlan.parse(plan)
    if reducer is not None:
        p = p.with_reducer(reducer)
    return apply_bucketing(
        p, getattr(hier, "bucket_bytes", DEFAULT_BUCKET_BYTES),
        getattr(hier, "overlap", True), shards=shards)


def init_comm_state(plan: ReductionPlan, params):
    """Per-level reducer carry keyed by level name (stateful levels only —
    topk error feedback at the local level must not pollute global EF).
    All-stateless plans keep the legacy ``()`` so TrainState is unchanged
    on the default path."""
    state = {lvl.name: lvl.reducer.init_state(params)
             for lvl in plan.levels if lvl.reducer.stateful}
    return state if state else ()
