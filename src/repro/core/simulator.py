"""Single-device Hier-AVG simulator.

Runs P learners on one CPU device with the *same* stacked-learner code as
the distributed trainer (core/hier_avg.py) — only the shardings are absent.
Used by the paper-validation benchmarks (K2 / K1 / S sweeps, vs-K-AVG) and
the convergence tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import Reducer
from repro.configs.base import HierAvgParams
from repro.core.baselines import make_kavg_round, make_sync_sgd_round
from repro.core.hier_avg import TrainState, init_state, make_hier_round
from repro.core.plan import (LEVEL_AXES, ReductionLevel, ReductionPlan,
                             resolve_plan)
from repro.core.topology import HierTopology, unstack_first
from repro.optim import Optimizer, sgd


@dataclasses.dataclass
class SimResult:
    losses: np.ndarray          # per-round mean training loss
    accs: np.ndarray            # per-round mean training accuracy
    eval_losses: np.ndarray     # per-round eval loss of the averaged model
    eval_accs: np.ndarray
    grad_sq_norms: np.ndarray   # ||grad F(w~_n)||^2 proxy at global syncs
    state: TrainState
    # elastic (faults=) runs only: per-round participation fraction per
    # plan level [n_rounds, n_levels] and the modeled round wall seconds
    # under that round's actual participation
    active_fracs: Optional[np.ndarray] = None
    round_wall_s: Optional[np.ndarray] = None
    # metrics= runs only: measured per-round wall seconds (the round is
    # fenced with block_until_ready — the documented telemetry cost)
    measured_wall_s: Optional[np.ndarray] = None
    # telemetry= runs only: per-round means of every device-side
    # ``telemetry/...`` stat key (gradstats.py), [n_rounds] each
    stats: Optional[Dict[str, np.ndarray]] = None

    @property
    def final_eval_acc(self) -> float:
        return float(self.eval_accs[-1])


class Simulator:
    """Hier-AVG / K-AVG / sync-SGD on one device.

    loss_fn(params, batch) -> (loss, metrics with 'loss' and 'accuracy').
    sample_batch(key, n) -> batch with leading dim n (token/example axis 0 on
    every leaf).
    """

    def __init__(self, loss_fn: Callable, init_fn: Callable,
                 sample_batch: Callable, *, topo: HierTopology,
                 hier: HierAvgParams, optimizer: Optional[Optimizer] = None,
                 algo: str = "hier", per_learner_batch: int = 32,
                 eval_batch: Optional[Any] = None, seed: int = 0,
                 reducer: Optional[Any] = None, faults: Optional[Any] = None,
                 comm_model: Optional[Any] = None,
                 telemetry: Any = None, metrics: Optional[Any] = None):
        self.loss_fn = loss_fn
        self.init_fn = init_fn
        self.sample = sample_batch
        self.topo = topo
        self.hier = hier
        self.optimizer = optimizer or sgd(0.1)
        self.B = per_learner_batch
        self.eval_batch = eval_batch
        self.key = jax.random.PRNGKey(seed)
        # the plan actually trained: hier.plan / legacy (k1,k2,reducer),
        # with an explicit ``reducer`` spec/instance overriding every level
        self.plan: ReductionPlan = resolve_plan(hier, reducer)
        # outermost level's reducer == the legacy single-reducer view
        self.reducer: Reducer = self.plan.levels[-1].reducer
        # elastic membership: a FaultSchedule (or spec string — parsed
        # against this plan's levels, with straggler deadlines priced
        # from the CommModel level walls) drives per-round participation
        # masks through the elastic round program
        self.comm_model = comm_model
        self.faults = None
        if faults is not None:
            if algo != "hier":
                raise ValueError(
                    f"fault injection needs the elastic hier round "
                    f"program; algo={algo!r} does not take masks")
            from repro.elastic import FaultSchedule, level_deadlines
            if isinstance(faults, FaultSchedule):
                self.faults = faults
            else:
                params1 = jax.eval_shape(
                    self.init_fn, jax.ShapeDtypeStruct((2,), jnp.uint32))
                self.faults = FaultSchedule(
                    faults, topo, [lvl.name for lvl in self.plan.levels],
                    seed=seed,
                    deadlines=level_deadlines(self.plan, topo, params1,
                                              comm_model))
        # the round batch nest must match the round function actually
        # built: the baselines are 2-level rounds, so an N-level hier's
        # batch collapses to (1, steps) for them
        legacy_dims = hier.batch_dims if len(hier.batch_dims) == 2 \
            else (1, hier.steps_per_round)
        # telemetry= (repro/telemetry gradstats knob, hier only) adds
        # device-side stat keys; metrics= (a MetricsLogger) receives one
        # structured train_round row per round, with the round fenced so
        # its wall is measured (the documented opt-in cost)
        self.telemetry = telemetry
        self.metrics = metrics
        if telemetry and algo != "hier":
            raise ValueError(
                f"telemetry= needs the hier round program; algo={algo!r} "
                f"has no per-level reduction to instrument")
        if algo == "hier":
            rnd = make_hier_round(loss_fn, self.optimizer, hier,
                                  reducer=reducer,
                                  elastic=self.faults is not None,
                                  telemetry=telemetry)
            self._batch_dims = self.plan.batch_dims
            self._init_plan = self.plan
        elif algo == "kavg":
            rnd = make_kavg_round(loss_fn, self.optimizer, hier.k2,
                                  reducer=self.reducer)
            self._batch_dims = legacy_dims
            # the baselines only ever reduce globally (skip_local), so a
            # 1-level plan avoids carrying an unused "local" EF state
            self._init_plan = ReductionPlan((ReductionLevel(
                "global", LEVEL_AXES["global"], hier.k2, self.reducer),))
        elif algo == "sync":
            rnd = make_sync_sgd_round(loss_fn, self.optimizer,
                                      reducer=self.reducer)
            self._batch_dims = legacy_dims
            self._init_plan = ReductionPlan((ReductionLevel(
                "global", LEVEL_AXES["global"], 1, self.reducer),))
        else:
            raise ValueError(algo)
        # donate the carried TrainState: params/opt_state/EF buffers update
        # in place instead of doubling peak memory every round
        self.round_fn = jax.jit(rnd, donate_argnums=(0,))
        self._eval = jax.jit(lambda p, b: self.loss_fn(p, b))
        self._gsq = jax.jit(self._grad_sq)

    def _grad_sq(self, params1, batch):
        g = jax.grad(lambda p: self.loss_fn(p, batch)[0])(params1)
        return sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                   for x in jax.tree.leaves(g))

    def _round_batch(self, key):
        n = self.hier.steps_per_round * self.topo.n_learners * self.B
        batch = self.sample(key, n)
        shape = self._batch_dims + self.topo.shape + (self.B,)
        return jax.tree.map(
            lambda x: x.reshape(shape + x.shape[1:]), batch)

    def payload_bytes_per_reduction(self) -> int:
        """Analytic per-learner wire bytes of one outermost (global)
        reduction under the configured plan (dense fp32 for "mean")."""
        params1 = jax.eval_shape(self.init_fn,
                                 jax.ShapeDtypeStruct((2,), jnp.uint32))
        return self.reducer.payload_bytes(params1)

    def payload_bytes_per_level(self) -> Dict[str, int]:
        """Per-level analytic wire bytes of one reduction at each plan
        level (per learner)."""
        params1 = jax.eval_shape(self.init_fn,
                                 jax.ShapeDtypeStruct((2,), jnp.uint32))
        return {lvl.name: lvl.reducer.payload_bytes(params1)
                for lvl in self.plan.levels}

    def round_wall_estimate(self, fracs) -> float:
        """Modeled wall seconds of one round whose per-level participation
        fractions were ``fracs`` (aligned with ``plan.levels``): each
        level's billable count times its scheduled wall at an effective
        drop probability of ``1 - frac`` (core/theory.py n_eff billing).
        Memoized on the fraction tuple — a fleet takes few distinct
        participation patterns, and repricing every round would dominate
        small-model round wall."""
        from repro.core.theory import level_reduction_seconds
        key = tuple(round(float(f), 6) for f in fracs)
        cache = getattr(self, "_wall_cache", None)
        if cache is None:
            cache = self._wall_cache = {}
        if key in cache:
            return cache[key]
        params1 = jax.eval_shape(self.init_fn,
                                 jax.ShapeDtypeStruct((2,), jnp.uint32))
        counts = dict(self.plan.counts_per_round())
        wall = 0.0
        for lvl, f in zip(self.plan.levels, key):
            wall += counts[lvl.name] * level_reduction_seconds(
                lvl, self.topo, params1, self.comm_model,
                drop_prob=1.0 - f)[2]
        cache[key] = wall
        return wall

    def run(self, n_rounds: int, key=None) -> SimResult:
        # Per-round scalars are BUFFERED as device arrays and fetched
        # with ONE jax.device_get at the end — the old per-key float()
        # calls forced a blocking device->host transfer per metric per
        # round (the PR-10 host-sync hotspot).  Participation fractions
        # come from the host-side FaultSchedule mask (no device read).
        # The jit donates the carried state, never the metrics, so held
        # metric buffers stay valid across rounds.  With a metrics=
        # logger attached each round is fenced (block_until_ready) to
        # measure its wall — that serialization is the logger's
        # documented cost, off by default.
        key = self.key if key is None else key
        k_init, key = jax.random.split(key)
        state = init_state(self.topo, self.init_fn, self.optimizer, k_init,
                           plan=self._init_plan)
        dev_rounds, dev_evals = [], []
        fracs, walls, measured = [], [], []
        observe = self.metrics is not None
        for r in range(n_rounds):
            key, kb = jax.random.split(key)
            batch = self._round_batch(kb)
            t0 = time.perf_counter() if observe else 0.0
            if self.faults is not None:
                active = jnp.asarray(self.faults.active(r))
                state, metrics = self.round_fn(state, batch, active)
                f = [float(x) for x in self.faults.active_frac(r)]
                fracs.append(f)
                walls.append(self.round_wall_estimate(f))
            else:
                state, metrics = self.round_fn(state, batch)
            if observe:
                jax.block_until_ready(metrics)
                measured.append(time.perf_counter() - t0)
            dev_rounds.append(metrics)
            if self.eval_batch is not None:
                p1 = unstack_first(state.params)
                el, em = self._eval(p1, self.eval_batch)
                dev_evals.append((el, em.get("accuracy", jnp.nan),
                                  self._gsq(p1, self.eval_batch)))
        rounds, evals = jax.device_get((dev_rounds, dev_evals))
        losses = np.array([float(m["loss"]) for m in rounds])
        accs = np.array([float(m.get("accuracy", np.nan)) for m in rounds])
        elosses = np.array([float(e[0]) for e in evals])
        eaccs = np.array([float(e[1]) for e in evals])
        gsq = np.array([float(e[2]) for e in evals])
        stat_keys = [k for k in (rounds[0] if rounds else {})
                     if k.startswith("telemetry/")]
        stats = {k: np.array([float(m[k]) for m in rounds])
                 for k in stat_keys} or None
        res = SimResult(losses, accs, elosses, eaccs, gsq, state,
                        active_fracs=np.array(fracs) if fracs else None,
                        round_wall_s=np.array(walls) if walls else None,
                        measured_wall_s=(np.array(measured)
                                         if measured else None),
                        stats=stats)
        if observe:
            self._log_rows(res, n_rounds, rounds)
        return res

    def _log_rows(self, res: SimResult, n_rounds: int, rounds) -> None:
        """One schema-versioned train_round row per round (telemetry/
        metrics.py) plus the typed-channel aggregates."""
        names = [lvl.name for lvl in self.plan.levels]
        for r in range(n_rounds):
            row = {"round": r, "loss": float(res.losses[r]),
                   "accuracy": float(res.accs[r]),
                   "wall_s": float(res.measured_wall_s[r]),
                   "plan": self.plan.describe()}
            if res.active_fracs is not None:
                row["active_frac"] = dict(
                    zip(names, (float(f) for f in res.active_fracs[r])))
                row["modeled_wall_s"] = float(res.round_wall_s[r])
            if res.stats:
                row.update({k: float(v[r]) for k, v in res.stats.items()})
            self.metrics.log_row("train_round", **row)
            self.metrics.count("train/rounds")
            self.metrics.histogram("train/round_wall_s", row["wall_s"])
        self.metrics.gauge("train/loss", float(res.losses[-1]))
        self.metrics.flush()


def run_algo_comparison(loss_fn, init_fn, sample_batch, eval_batch, *,
                        variants: Dict[str, Dict], n_rounds: int,
                        per_learner_batch: int = 32, seed: int = 0
                        ) -> Dict[str, SimResult]:
    """Run several (algo, topo, hier) variants with the same seed/data."""
    out = {}
    for name, spec in variants.items():
        sim = Simulator(loss_fn, init_fn, sample_batch,
                        topo=spec["topo"], hier=spec["hier"],
                        optimizer=spec.get("optimizer"),
                        algo=spec.get("algo", "hier"),
                        reducer=spec.get("reducer"),
                        faults=spec.get("faults"),
                        per_learner_batch=per_learner_batch,
                        eval_batch=eval_batch, seed=seed)
        out[name] = sim.run(n_rounds)
    return out
