"""Hier-AVG (Algorithm 1) as a composable JAX trainer.

The whole K2-step cycle ("round") is one jitted program built from nested
``lax.scan``s, exactly mirroring Algorithm 1:

    for b in 0..beta-1:          # beta = K2 / K1
        for k in 1..K1:          #   local SGD steps
            w_j <- w_j - gamma/B sum grad F(w_j; xi)
        w_j <- mean over cluster (S learners)        # local reduction
    w~ <- mean over all P learners                   # global reduction

Parameters/optimizer state live in the stacked-learner layout
[pods, G, S, *shape]; per-learner gradients come from one ``jax.grad`` of the
summed per-learner losses through a triple ``vmap``.  The two reductions are
``jnp.mean``s over the stacked axes (see core/topology.py) which GSPMD turns
into grouped all-reduces over the matching mesh axes.

The same code runs on a single CPU device (simulator / tests — no mesh) and
on the 512-chip multi-pod mesh (launch/dryrun.py supplies shardings).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm import Reducer, get_reducer, reduce_with
from repro.configs.base import HierAvgParams
from repro.core.topology import (HierTopology, global_average, local_average,
                                 stack_like)
from repro.optim import Optimizer


class TrainState(NamedTuple):
    params: Any          # leaves [pods, G, S, *shape]
    opt_state: Any       # same stacking
    step: jax.Array      # scalar int32 — local SGD steps taken
    comm_state: Any = () # reducer carry (comm/): EF residuals etc.


def init_state(topo: HierTopology, init_fn, optimizer: Optimizer, key,
               reducer: Optional[Reducer] = None) -> TrainState:
    """All learners start from the same w_1 (paper's initialization).

    ``reducer`` must match the one the round/step function was built with
    (stateful reducers carry per-learner state in ``comm_state``).
    """
    params1 = init_fn(key)
    params = stack_like(topo, params1)
    opt_state = optimizer.init(params)
    comm_state = reducer.init_state(params) if reducer is not None else ()
    return TrainState(params, opt_state, jnp.zeros((), jnp.int32),
                      comm_state)


def stacked_grad_fn(loss_fn: Callable):
    """loss_fn(params, batch) -> (loss, metrics), single learner.

    Returns grad_fn(stacked_params, stacked_batch) -> (grads, metrics) where
    grads are per-learner (stacked) and metrics keep the learner axes.
    """
    f = loss_fn
    for _ in range(3):
        f = jax.vmap(f)

    def total(params, batch):
        losses, metrics = f(params, batch)
        return losses.sum(), metrics

    return jax.grad(total, has_aux=True)


def make_sgd_step(loss_fn: Callable, optimizer: Optimizer,
                  grad_postprocess: Optional[Callable] = None,
                  microbatch: int = 1):
    """One local SGD step on all learners concurrently.

    ``microbatch > 1`` splits each learner's per-step batch (dim 3 of every
    leaf, after the [pods, G, S] axes) into that many slices and accumulates
    gradients over a ``lax.scan`` — activation memory drops by the factor,
    FLOPs unchanged.
    """
    grad_fn = stacked_grad_fn(loss_fn)

    def one_shot(state: TrainState, batch):
        return grad_fn(state.params, batch)

    def accumulated(state: TrainState, batch):
        def split(x):
            b = x.shape[3]
            assert b % microbatch == 0, (x.shape, microbatch)
            y = x.reshape(x.shape[:3] + (microbatch, b // microbatch)
                          + x.shape[4:])
            return jnp.moveaxis(y, 3, 0)      # [m, pods, G, S, b/m, ...]

        micro = jax.tree.map(split, batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

        def acc(g, mb):
            grads, metrics = grad_fn(state.params, mb)
            g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                             g, grads)
            return g, metrics

        grads, ms = jax.lax.scan(acc, zeros, micro)
        grads = jax.tree.map(lambda g: g / microbatch, grads)
        metrics = jax.tree.map(lambda m: m.mean(0), ms)
        return grads, metrics

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        if microbatch == 1:
            grads, metrics = one_shot(state, batch)
        else:
            grads, metrics = accumulated(state, batch)
        if grad_postprocess is not None:
            grads = grad_postprocess(grads)
        params, opt_state = optimizer.update(grads, state.params,
                                             state.opt_state, state.step)
        return state._replace(params=params, opt_state=opt_state,
                              step=state.step + 1), metrics

    return step


def resolve_reducer(hier: HierAvgParams,
                    reducer: Optional[Any] = None) -> Reducer:
    """An explicit ``reducer`` (spec string or instance) wins; otherwise the
    config's ``hier.reducer`` spec decides (default "mean")."""
    if reducer is not None:
        return get_reducer(reducer)
    return get_reducer(getattr(hier, "reducer", "mean"))


def make_hier_round(loss_fn: Callable, optimizer: Optimizer,
                    hier: HierAvgParams, *,
                    sync_opt_state: bool = False,
                    skip_local: bool = False,
                    constraint_fn: Optional[Callable] = None,
                    grad_postprocess: Optional[Callable] = None,
                    microbatch: int = 1,
                    reducer: Optional[Any] = None):
    """Build the jitted Hier-AVG round.

    round(state, round_batch) -> (state, metrics); round_batch leaves are
    shaped [beta, K1, pods, G, S, *per_learner_batch].

    ``skip_local=True`` turns the round into K-AVG with K = K2 (baseline).
    ``sync_opt_state`` additionally averages optimizer state at each
    reduction (beyond-paper option; default False keeps momentum local,
    matching the paper's parameter-only averaging).

    ``reducer`` (comm/): how each reduction's payload is compressed — a
    spec string ("mean", "cast:bfloat16", "topk:0.1", ...), a Reducer
    instance, or None to use ``hier.reducer``.  Parameters go through the
    reducer; optimizer state (when ``sync_opt_state``) is always dense mean.
    Stateful reducers carry ``TrainState.comm_state`` — build the initial
    state with ``init_state(..., reducer=...)``.
    """
    sgd_step = make_sgd_step(loss_fn, optimizer, grad_postprocess,
                             microbatch=microbatch)
    red = resolve_reducer(hier, reducer)

    def _reduce(avg_fn, state: TrainState) -> TrainState:
        params, comm_state = reduce_with(red, avg_fn, state.params,
                                         state.comm_state, constraint_fn)
        if sync_opt_state:
            state = state._replace(
                opt_state=avg_fn(state.opt_state, constraint_fn))
        return state._replace(params=params, comm_state=comm_state)

    def local_phase(state: TrainState, batches):
        """K1 SGD steps then one local reduction."""
        state, metrics = jax.lax.scan(sgd_step, state, batches)
        if not skip_local:
            state = _reduce(local_average, state)
        return state, metrics

    def round_fn(state: TrainState, round_batch):
        state, metrics = jax.lax.scan(local_phase, state, round_batch)
        state = _reduce(global_average, state)
        # metrics leaves: [beta, K1, pods, G, S] -> scalar means
        metrics = jax.tree.map(lambda m: m.mean(), metrics)
        return state, metrics

    return round_fn


# --------------------------------------------------------------------- #
# step-wise API (serving-style loops / adaptive schedules)
# --------------------------------------------------------------------- #

def make_hier_step(loss_fn: Callable, optimizer: Optimizer,
                   hier: HierAvgParams, *,
                   skip_local: bool = False,
                   constraint_fn: Optional[Callable] = None,
                   reducer: Optional[Any] = None):
    """Single-step variant: applies local/global averaging via masking on the
    step counter.  Semantics identical to the round API; useful when K1/K2
    change adaptively between rounds.

    Reducers apply here too (compress runs every step; the result and any
    carried comm state are masked in only on reduction steps).  The K2-step
    equivalence with ``make_hier_round`` is exact for the dense "mean"
    reducer (tests/test_hier_avg.py::test_step_api_matches_round_api); for
    compressed reducers the round API fuses the final local+global
    reductions while the step API applies only the global one, so the two
    trajectories differ by the compression of an already-averaged delta.
    """
    sgd_step = make_sgd_step(loss_fn, optimizer)
    red = resolve_reducer(hier, reducer)

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        state, metrics = sgd_step(state, batch)
        t = state.step  # steps completed
        do_local = jnp.logical_and((t % hier.k1) == 0,
                                   (t % hier.k2) != 0)
        do_global = (t % hier.k2) == 0

        def blend(new_tree, old_tree, mask):
            return jax.tree.map(
                lambda a, p: jnp.where(mask, a, p), new_tree, old_tree)

        params, cs = state.params, state.comm_state
        if not skip_local:
            red_p, red_cs = reduce_with(red, local_average, params, cs,
                                        constraint_fn)
            params = blend(red_p, params, do_local)
            cs = blend(red_cs, cs, do_local)
        red_p, red_cs = reduce_with(red, global_average, params, cs,
                                    constraint_fn)
        params = blend(red_p, params, do_global)
        cs = blend(red_cs, cs, do_global)
        return state._replace(params=params, comm_state=cs), metrics

    return step


# --------------------------------------------------------------------- #
# batch reshaping helpers
# --------------------------------------------------------------------- #

def round_batch_shape(hier: HierAvgParams, topo: HierTopology,
                      per_learner_batch: int) -> Tuple[int, ...]:
    return (hier.beta, hier.k1) + topo.shape + (per_learner_batch,)


def shard_round_batch(batch, hier: HierAvgParams, topo: HierTopology):
    """Reshape leaves [beta*K1*P*B, ...] -> [beta, K1, pods, G, S, B, ...]."""
    def rs(x):
        total = hier.beta * hier.k1 * topo.n_learners
        b = x.shape[0] // total
        return x.reshape((hier.beta, hier.k1) + topo.shape + (b,)
                         + x.shape[1:])
    return jax.tree.map(rs, batch)
