"""Hier-AVG (Algorithm 1) as a composable JAX trainer, generalized to an
N-level :class:`~repro.core.plan.ReductionPlan`.

A round is one jitted program built as a recursive nest of ``lax.scan``s —
one scan per plan level, innermost first:

    level 0:  p_1 SGD steps, then the level-0 reduction
    level i:  (p_{i+1}/p_i) runs of level i-1, then the level-i reduction

The paper's Algorithm 1 is the 2-level plan ``local@K1 / global@K2``
(``beta = K2/K1`` runs of K1 local steps + cluster averaging, then one
global averaging), which legacy ``HierAvgParams(k1, k2)`` builds
bit-identically.  A 3-level ICI/DCI-aligned plan adds a ``pod`` rung.

Parameters/optimizer state live in the stacked-learner layout
[pods, G, S, *shape]; per-learner gradients come from one ``jax.grad`` of
the summed per-learner losses through a triple ``vmap``.  Each level's
reduction is a ``jnp.mean`` over that level's stacked axes (see
core/topology.py) which GSPMD turns into grouped all-reduces over the
matching mesh axes, optionally compressed per level by a comm/ Reducer.

The same code runs on a single CPU device (simulator / tests — no mesh)
and on the 512-chip multi-pod mesh (launch/dryrun.py supplies shardings).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm import Reducer, reduce_with
from repro.configs.base import HierAvgParams
from repro.core.plan import (PlanLike, ReductionLevel, ReductionPlan,
                             apply_bucketing, apply_shards, init_comm_state,
                             resolve_plan)
from repro.core.topology import (HierTopology, average_over, stack_like,
                                 where_active)
from repro.optim import Optimizer


class TrainState(NamedTuple):
    params: Any          # leaves [pods, G, S, *shape]
    opt_state: Any       # same stacking
    step: jax.Array      # scalar int32 — local SGD steps taken
    comm_state: Any = () # per-level reducer carry (comm/), keyed by level
                         # name; () when no level is stateful


def init_state(topo: HierTopology, init_fn, optimizer: Optimizer, key,
               reducer: Optional[Reducer] = None,
               plan: PlanLike = None,
               bucket_bytes: Optional[int] = None,
               overlap: Optional[bool] = None,
               shards: Optional[Any] = None) -> TrainState:
    """All learners start from the same w_1 (paper's initialization).

    ``plan`` (or legacy ``reducer``) must match what the round/step
    function was built with: stateful reducers carry per-level state in
    ``comm_state`` keyed by level name.  Passing only ``reducer`` builds
    the default 2-level (local/global) state for it.

    Bucketing must agree with the round builder's ``resolve_plan``
    (comm/bucket.py): a ``plan`` given as a spec string, or a bare
    ``reducer``, gets the same default bucketing a default
    ``HierAvgParams`` resolves to; pass ``bucket_bytes`` (0 = per-leaf)
    and/or ``overlap=False`` when the round uses non-default
    ``HierAvgParams.bucket_bytes`` / ``HierAvgParams.overlap`` (the
    pipelined engine pads multi-bucket layouts uniform, so its EF state
    shapes differ from the serial schedule's).  A ``ReductionPlan``
    *instance* is taken as already resolved (e.g. ``hier.resolved_plan``)
    unless ``bucket_bytes`` or ``overlap`` is given explicitly — an
    explicit ``overlap`` re-chooses the bucket engine (demoting
    auto-pipelined wrappers to the serial schedule and vice versa; each
    wrapper keeps its own cap when ``bucket_bytes`` stays None).

    ``shards`` — the :class:`~repro.parallel.sharding.ShardPlan` the
    round/step builder was given (fsdp>1 meshes); bucketed reducers then
    carry error-feedback state in *shard space* (codec view), so it must
    match or the state shapes are wrong.
    """
    from repro.comm import DEFAULT_BUCKET_BYTES
    params1 = init_fn(key)
    params = stack_like(topo, params1)
    opt_state = optimizer.init(params)
    ov = True if overlap is None else overlap
    if plan is not None:
        if isinstance(plan, ReductionPlan):
            p = apply_shards(plan, shards) \
                if (bucket_bytes is None and overlap is None) \
                else apply_bucketing(
                    plan, 0 if bucket_bytes is None else bucket_bytes, ov,
                    shards=shards)
        else:
            p = apply_bucketing(
                ReductionPlan.parse(plan),
                DEFAULT_BUCKET_BYTES if bucket_bytes is None
                else bucket_bytes, ov, shards=shards)
        comm_state = init_comm_state(p, params)
    elif reducer is not None:
        comm_state = init_comm_state(
            apply_bucketing(ReductionPlan.from_k1_k2(1, 1, reducer),
                            DEFAULT_BUCKET_BYTES if bucket_bytes is None
                            else bucket_bytes, ov, shards=shards), params)
    else:
        comm_state = ()
    return TrainState(params, opt_state, jnp.zeros((), jnp.int32),
                      comm_state)


def stacked_grad_fn(loss_fn: Callable):
    """loss_fn(params, batch) -> (loss, metrics), single learner.

    Returns grad_fn(stacked_params, stacked_batch) -> (grads, metrics) where
    grads are per-learner (stacked) and metrics keep the learner axes.
    """
    f = loss_fn
    for _ in range(3):
        f = jax.vmap(f)

    def total(params, batch):
        losses, metrics = f(params, batch)
        return losses.sum(), metrics

    return jax.grad(total, has_aux=True)


def make_sgd_step(loss_fn: Callable, optimizer: Optimizer,
                  grad_postprocess: Optional[Callable] = None,
                  microbatch: int = 1,
                  grad_observer: Optional[Callable] = None):
    """One local SGD step on all learners concurrently.

    ``microbatch > 1`` splits each learner's per-step batch (dim 3 of every
    leaf, after the [pods, G, S] axes) into that many slices and accumulates
    gradients over a ``lax.scan`` — activation memory drops by the factor,
    FLOPs unchanged.

    ``grad_observer`` (telemetry/gradstats.py): a pure function of the
    stacked per-learner gradients returning extra scalar metrics keys —
    a read-only tap, the update itself is untouched.
    """
    grad_fn = stacked_grad_fn(loss_fn)

    def one_shot(state: TrainState, batch):
        return grad_fn(state.params, batch)

    def accumulated(state: TrainState, batch):
        def split(x):
            b = x.shape[3]
            assert b % microbatch == 0, (x.shape, microbatch)
            y = x.reshape(x.shape[:3] + (microbatch, b // microbatch)
                          + x.shape[4:])
            return jnp.moveaxis(y, 3, 0)      # [m, pods, G, S, b/m, ...]

        micro = jax.tree.map(split, batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

        def acc(g, mb):
            grads, metrics = grad_fn(state.params, mb)
            g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                             g, grads)
            return g, metrics

        grads, ms = jax.lax.scan(acc, zeros, micro)
        grads = jax.tree.map(lambda g: g / microbatch, grads)
        metrics = jax.tree.map(lambda m: m.mean(0), ms)
        return grads, metrics

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        if microbatch == 1:
            grads, metrics = one_shot(state, batch)
        else:
            grads, metrics = accumulated(state, batch)
        if grad_observer is not None:
            metrics = dict(metrics)
            metrics.update(grad_observer(grads))
        if grad_postprocess is not None:
            grads = grad_postprocess(grads)
        params, opt_state = optimizer.update(grads, state.params,
                                             state.opt_state, state.step)
        return state._replace(params=params, opt_state=opt_state,
                              step=state.step + 1), metrics

    return step


def _make_reduce(constraint_fn, sync_opt_state):
    """reduce(level, state, active=None) -> state after one compressed
    reduction at that level, touching only that level's comm_state entry.

    ``active`` (elastic membership, repro/elastic): a boolean
    ``[pods, G, S]`` participation mask.  The grouped mean renormalizes
    over the present learners only (core/topology.py ``average_over``),
    and absent learners keep their own params AND their EF/``comm_state``
    untouched across the missed fire (``where_active`` select) — a
    learner that missed a reduction neither contributes to nor observes
    it.  ``active=None`` is the dense path, bit-identical to before.
    """

    def reduce(level: ReductionLevel, state: TrainState,
               active=None) -> TrainState:
        avg_fn = lambda tree, cf=None, specs=None: average_over(  # noqa: E731
            tree, level.axes, cf, specs, active)
        if level.reducer.stateful:
            params, lvl_cs = reduce_with(
                level.reducer, avg_fn, state.params,
                state.comm_state[level.name], constraint_fn)
            if active is not None:
                lvl_cs = where_active(active, lvl_cs,
                                      state.comm_state[level.name])
            comm_state = dict(state.comm_state)
            comm_state[level.name] = lvl_cs
        else:
            params, _ = reduce_with(level.reducer, avg_fn, state.params,
                                    (), constraint_fn)
            comm_state = state.comm_state
        if active is not None:
            params = where_active(active, params, state.params)
        if sync_opt_state:
            opt = avg_fn(state.opt_state, constraint_fn)
            if active is not None:
                opt = where_active(active, opt, state.opt_state)
            state = state._replace(opt_state=opt)
        return state._replace(params=params, comm_state=comm_state)

    return reduce


def make_hier_round(loss_fn: Callable, optimizer: Optimizer,
                    hier: HierAvgParams, *,
                    sync_opt_state: bool = False,
                    skip_local: bool = False,
                    constraint_fn: Optional[Callable] = None,
                    grad_postprocess: Optional[Callable] = None,
                    microbatch: int = 1,
                    reducer: Optional[Any] = None,
                    plan: PlanLike = None,
                    shards: Optional[Any] = None,
                    elastic: bool = False,
                    telemetry: Any = None):
    """Build the jitted Hier-AVG round for an N-level reduction plan.

    round(state, round_batch) -> (state, metrics); round_batch leaves are
    shaped [*hier.batch_dims, pods, G, S, *per_learner_batch] — for the
    legacy 2-level plan that is the familiar [beta, K1, ...].

    ``elastic=True`` builds the participation-masked round instead:
    ``round(state, round_batch, active) -> (state, metrics)`` with
    ``active`` a boolean ``[n_levels, pods, G, S]`` mask (level *i* of the
    plan, innermost first, uses ``active[i]`` for every one of its fires
    this round).  Absent learners contribute weight 0 to that level's
    renormalized mean and keep their params and EF state untouched
    (see ``_make_reduce``); metrics gain ``active_frac/<level>``.  With
    an all-true mask the round is bit-identical to the dense build.

    ``plan`` — a ReductionPlan, a spec string
    ("local@4:cast:bfloat16/pod@8/global@16:topk:0.05"), or None to use
    ``hier.plan`` / the legacy 2-level plan from ``hier.k1``/``hier.k2``.

    ``skip_local=True`` skips every reduction except the outermost (for
    the 2-level plan this turns the round into K-AVG with K = K2).
    ``sync_opt_state`` additionally averages optimizer state at each
    reduction (beyond-paper option; default False keeps momentum local,
    matching the paper's parameter-only averaging).

    ``reducer`` (comm/): legacy single-reducer override — replaces the
    reducer of EVERY level.  Per-level reducers come from the plan spec.
    Stateful reducers carry ``TrainState.comm_state`` keyed by level name —
    build the initial state with ``init_state(..., plan=...)``.

    ``shards`` (parallel/sharding.py ShardPlan): fsdp>1 meshes pack
    buckets shard-locally and lower each level's mean to
    reduce-scatter + all-gather; pass the same plan to ``init_state``.

    ``telemetry`` (repro/telemetry): ``True`` or a ``TelemetryConfig``
    adds device-side statistics to the round's metrics as cheap ``jnp``
    reductions — per-level pre/post-average parameter divergence (the
    Thm-3.2 discrepancy), cross-learner gradient-norm variance (the
    Jiang & Agrawal period trigger), EF residual mass, and codec
    compression error (``telemetry/...`` keys).  Pure observers: the
    training trajectory is bit-identical to ``telemetry=None``
    (gated by benchmarks/bench_telemetry.py).
    """
    from repro.telemetry.gradstats import (level_stats,
                                           make_grad_observer,
                                           resolve_telemetry)
    tcfg = resolve_telemetry(telemetry)
    p = resolve_plan(hier, reducer, plan, shards=shards)
    sgd_step = make_sgd_step(loss_fn, optimizer, grad_postprocess,
                             microbatch=microbatch,
                             grad_observer=make_grad_observer(
                                 tcfg, p.levels) if tcfg else None)
    _reduce = _make_reduce(constraint_fn, sync_opt_state)
    last = len(p.levels) - 1

    if not elastic:
        def make_phase(inner, level: ReductionLevel, skipped: bool):
            """scan ``inner`` over this level's leading batch dim, then
            apply this level's reduction."""
            def phase(state: TrainState, batches):
                state, metrics = jax.lax.scan(inner, state, batches)
                if not skipped:
                    pre = state.params if tcfg is not None else None
                    state = _reduce(level, state)
                    if tcfg is not None:
                        metrics = dict(metrics)
                        metrics.update(level_stats(
                            tcfg, level, pre, state.params,
                            state.comm_state))
                return state, metrics
            return phase

        phase = sgd_step
        for i, level in enumerate(p.levels):
            phase = make_phase(phase, level, skip_local and i < last)

        def round_fn(state: TrainState, round_batch):
            state, metrics = phase(state, round_batch)
            # metrics leaves: [*batch_dims, pods, G, S] -> scalar means
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
            return state, metrics

        return round_fn

    # elastic build: the per-level masks ride the scan carry next to the
    # TrainState so every nesting depth sees them
    def estep(carry, batch):
        state, active = carry
        state, metrics = sgd_step(state, batch)
        return (state, active), metrics

    def make_ephase(inner, level: ReductionLevel, skipped: bool, i: int):
        def phase(carry, batches):
            carry, metrics = jax.lax.scan(inner, carry, batches)
            state, active = carry
            if not skipped:
                pre = state.params if tcfg is not None else None
                state = _reduce(level, state, active[i])
                if tcfg is not None:
                    # absent learners keep their (stale) params and
                    # count toward divergence — informative, not a bug
                    metrics = dict(metrics)
                    metrics.update(level_stats(
                        tcfg, level, pre, state.params,
                        state.comm_state))
            return (state, active), metrics
        return phase

    ephase = estep
    for i, level in enumerate(p.levels):
        ephase = make_ephase(ephase, level, skip_local and i < last, i)

    def elastic_round_fn(state: TrainState, round_batch, active):
        assert active.shape == (len(p.levels),) + tuple(
            jax.tree.leaves(state.params)[0].shape[:3]), (
            f"active mask must be [n_levels, pods, G, S] = "
            f"{(len(p.levels),)} + learner grid, got {active.shape}")
        (state, _), metrics = ephase((state, active), round_batch)
        metrics = dict(jax.tree.map(lambda m: m.mean(), metrics))
        for i, lvl in enumerate(p.levels):
            metrics[f"active_frac/{lvl.name}"] = \
                active[i].astype(jnp.float32).mean()
        return state, metrics

    return elastic_round_fn


# --------------------------------------------------------------------- #
# step-wise API (serving-style loops / adaptive schedules)
# --------------------------------------------------------------------- #

def make_hier_step(loss_fn: Callable, optimizer: Optimizer,
                   hier: HierAvgParams, *,
                   skip_local: bool = False,
                   constraint_fn: Optional[Callable] = None,
                   reducer: Optional[Any] = None,
                   plan: PlanLike = None,
                   shards: Optional[Any] = None,
                   elastic: bool = False):
    """Single-step variant: per-level counter masking on the step counter.

    ``elastic=True`` builds ``step(state, batch, active)`` with ``active``
    a boolean ``[n_levels, pods, G, S]`` participation mask; a firing
    level reduces over its present learners only, and absent learners
    keep their params/EF untouched (same semantics as the elastic
    ``make_hier_round``).  An all-true mask is bit-identical to the
    dense build.

    Level i fires when ``t % period_i == 0`` and the next level does NOT
    fire (an outer reduction subsumes all inner ones at the same step);
    the outermost level fires whenever its period divides t.  Semantics
    identical to the round API; useful when periods change adaptively
    between rounds (core/schedules.py AdaptivePlan).

    Each level's reduction sits under a ``lax.cond`` on its fire
    predicate, so non-firing steps skip the compress AND the grouped
    collective entirely (they used to run every step and be masked out
    with ``jnp.where`` — paying the full wire and kernel bill K2 times
    per round instead of the plan's billable counts).  The total-period
    equivalence with ``make_hier_round`` is exact for dense/stateless
    reducers (tests/test_plan.py::test_step_api_matches_round_api_3level);
    for error-feedback reducers the round API reduces inner levels at
    outer boundaries too (subsumed in time, not in the nest), so
    trajectories differ by the compression of an already-averaged delta.
    """
    sgd_step = make_sgd_step(loss_fn, optimizer)
    p = resolve_plan(hier, reducer, plan, shards=shards)
    last = len(p.levels) - 1

    def step(state: TrainState, batch, active=None
             ) -> Tuple[TrainState, Dict]:
        if elastic:
            assert active is not None, \
                "elastic step needs the [n_levels, pods, G, S] active mask"
        state, metrics = sgd_step(state, batch)
        t = state.step  # steps completed
        params, cs = state.params, state.comm_state
        for i, level in enumerate(p.levels):
            if skip_local and i < last:
                continue
            fire = (t % level.period) == 0
            if i < last:
                fire = jnp.logical_and(
                    fire, (t % p.levels[i + 1].period) != 0)
            mask = active[i] if elastic else None
            avg_fn = (lambda lv, mk: lambda tree, cf=None, specs=None:
                      average_over(tree, lv.axes, cf, specs, mk)
                      )(level, mask)
            lvl_cs = cs[level.name] if level.reducer.stateful else ()

            def reduce_branch(operand, level=level, avg_fn=avg_fn,
                              mask=mask):
                pp, lcs = operand
                out, ncs = reduce_with(level.reducer, avg_fn, pp, lcs,
                                       constraint_fn)
                if mask is not None:
                    out = where_active(mask, out, pp)
                    ncs = where_active(mask, ncs, lcs)
                return out, ncs

            params, lvl_cs = jax.lax.cond(
                fire, reduce_branch, lambda operand: operand,
                (params, lvl_cs))
            if level.reducer.stateful:
                cs = dict(cs)
                cs[level.name] = lvl_cs
        return state._replace(params=params, comm_state=cs), metrics

    return step


# --------------------------------------------------------------------- #
# batch reshaping helpers
# --------------------------------------------------------------------- #

def round_batch_shape(hier: HierAvgParams, topo: HierTopology,
                      per_learner_batch: int) -> Tuple[int, ...]:
    return hier.batch_dims + topo.shape + (per_learner_batch,)


def shard_round_batch(batch, hier: HierAvgParams, topo: HierTopology):
    """Reshape leaves [steps*P*B, ...] -> [*batch_dims, pods, G, S, B, ...]."""
    def rs(x):
        total = hier.steps_per_round * topo.n_learners
        b = x.shape[0] // total
        return x.reshape(hier.batch_dims + topo.shape + (b,) + x.shape[1:])
    return jax.tree.map(rs, batch)
