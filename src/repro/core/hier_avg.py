"""Hier-AVG (Algorithm 1) as a composable JAX trainer.

The whole K2-step cycle ("round") is one jitted program built from nested
``lax.scan``s, exactly mirroring Algorithm 1:

    for b in 0..beta-1:          # beta = K2 / K1
        for k in 1..K1:          #   local SGD steps
            w_j <- w_j - gamma/B sum grad F(w_j; xi)
        w_j <- mean over cluster (S learners)        # local reduction
    w~ <- mean over all P learners                   # global reduction

Parameters/optimizer state live in the stacked-learner layout
[pods, G, S, *shape]; per-learner gradients come from one ``jax.grad`` of the
summed per-learner losses through a triple ``vmap``.  The two reductions are
``jnp.mean``s over the stacked axes (see core/topology.py) which GSPMD turns
into grouped all-reduces over the matching mesh axes.

The same code runs on a single CPU device (simulator / tests — no mesh) and
on the 512-chip multi-pod mesh (launch/dryrun.py supplies shardings).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import HierAvgParams
from repro.core.topology import (HierTopology, global_average, local_average,
                                 stack_like)
from repro.optim import Optimizer


class TrainState(NamedTuple):
    params: Any          # leaves [pods, G, S, *shape]
    opt_state: Any       # same stacking
    step: jax.Array      # scalar int32 — local SGD steps taken


def init_state(topo: HierTopology, init_fn, optimizer: Optimizer, key
               ) -> TrainState:
    """All learners start from the same w_1 (paper's initialization)."""
    params1 = init_fn(key)
    params = stack_like(topo, params1)
    opt_state = optimizer.init(params)
    return TrainState(params, opt_state, jnp.zeros((), jnp.int32))


def stacked_grad_fn(loss_fn: Callable):
    """loss_fn(params, batch) -> (loss, metrics), single learner.

    Returns grad_fn(stacked_params, stacked_batch) -> (grads, metrics) where
    grads are per-learner (stacked) and metrics keep the learner axes.
    """
    f = loss_fn
    for _ in range(3):
        f = jax.vmap(f)

    def total(params, batch):
        losses, metrics = f(params, batch)
        return losses.sum(), metrics

    return jax.grad(total, has_aux=True)


def make_sgd_step(loss_fn: Callable, optimizer: Optimizer,
                  grad_postprocess: Optional[Callable] = None,
                  microbatch: int = 1):
    """One local SGD step on all learners concurrently.

    ``microbatch > 1`` splits each learner's per-step batch (dim 3 of every
    leaf, after the [pods, G, S] axes) into that many slices and accumulates
    gradients over a ``lax.scan`` — activation memory drops by the factor,
    FLOPs unchanged.
    """
    grad_fn = stacked_grad_fn(loss_fn)

    def one_shot(state: TrainState, batch):
        return grad_fn(state.params, batch)

    def accumulated(state: TrainState, batch):
        def split(x):
            b = x.shape[3]
            assert b % microbatch == 0, (x.shape, microbatch)
            y = x.reshape(x.shape[:3] + (microbatch, b // microbatch)
                          + x.shape[4:])
            return jnp.moveaxis(y, 3, 0)      # [m, pods, G, S, b/m, ...]

        micro = jax.tree.map(split, batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

        def acc(g, mb):
            grads, metrics = grad_fn(state.params, mb)
            g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                             g, grads)
            return g, metrics

        grads, ms = jax.lax.scan(acc, zeros, micro)
        grads = jax.tree.map(lambda g: g / microbatch, grads)
        metrics = jax.tree.map(lambda m: m.mean(0), ms)
        return grads, metrics

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        if microbatch == 1:
            grads, metrics = one_shot(state, batch)
        else:
            grads, metrics = accumulated(state, batch)
        if grad_postprocess is not None:
            grads = grad_postprocess(grads)
        params, opt_state = optimizer.update(grads, state.params,
                                             state.opt_state, state.step)
        return TrainState(params, opt_state, state.step + 1), metrics

    return step


def make_hier_round(loss_fn: Callable, optimizer: Optimizer,
                    hier: HierAvgParams, *,
                    sync_opt_state: bool = False,
                    skip_local: bool = False,
                    constraint_fn: Optional[Callable] = None,
                    grad_postprocess: Optional[Callable] = None,
                    microbatch: int = 1,
                    avg_dtype=None):
    """Build the jitted Hier-AVG round.

    round(state, round_batch) -> (state, metrics); round_batch leaves are
    shaped [beta, K1, pods, G, S, *per_learner_batch].

    ``skip_local=True`` turns the round into K-AVG with K = K2 (baseline).
    ``sync_opt_state`` additionally averages optimizer state at each
    reduction (beyond-paper option; default False keeps momentum local,
    matching the paper's parameter-only averaging).

    ``avg_dtype`` (beyond-paper): compute the reductions in a narrower dtype
    (e.g. jnp.bfloat16) — on hardware the all-reduce payload halves; the
    master params keep their dtype.  Convergence impact is validated in
    tests/test_hier_avg.py::test_bf16_averaging_converges.
    """
    sgd_step = make_sgd_step(loss_fn, optimizer, grad_postprocess,
                             microbatch=microbatch)

    def _avg(avg_fn, tree):
        if avg_dtype is None:
            return avg_fn(tree, constraint_fn)
        dtypes = jax.tree.map(lambda x: x.dtype, tree)
        narrowed = jax.tree.map(lambda x: x.astype(avg_dtype), tree)
        out = avg_fn(narrowed, constraint_fn)
        return jax.tree.map(lambda x, d: x.astype(d), out, dtypes)

    def maybe_sync_opt(opt_state, avg):
        if not sync_opt_state:
            return opt_state
        return _avg(avg, opt_state)

    def local_phase(state: TrainState, batches):
        """K1 SGD steps then one local reduction."""
        state, metrics = jax.lax.scan(sgd_step, state, batches)
        if not skip_local:
            state = state._replace(
                params=_avg(local_average, state.params),
                opt_state=maybe_sync_opt(state.opt_state, local_average))
        return state, metrics

    def round_fn(state: TrainState, round_batch):
        state, metrics = jax.lax.scan(local_phase, state, round_batch)
        state = state._replace(
            params=_avg(global_average, state.params),
            opt_state=maybe_sync_opt(state.opt_state, global_average))
        # metrics leaves: [beta, K1, pods, G, S] -> scalar means
        metrics = jax.tree.map(lambda m: m.mean(), metrics)
        return state, metrics

    return round_fn


# --------------------------------------------------------------------- #
# step-wise API (serving-style loops / adaptive schedules)
# --------------------------------------------------------------------- #

def make_hier_step(loss_fn: Callable, optimizer: Optimizer,
                   hier: HierAvgParams, *,
                   skip_local: bool = False,
                   constraint_fn: Optional[Callable] = None):
    """Single-step variant: applies local/global averaging via masking on the
    step counter.  Semantics identical to the round API; useful when K1/K2
    change adaptively between rounds."""
    sgd_step = make_sgd_step(loss_fn, optimizer)

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        state, metrics = sgd_step(state, batch)
        t = state.step  # steps completed
        do_local = jnp.logical_and((t % hier.k1) == 0,
                                   (t % hier.k2) != 0)
        do_global = (t % hier.k2) == 0

        def blend(avg_tree, mask):
            return jax.tree.map(
                lambda a, p: jnp.where(mask, a, p), avg_tree, state.params)

        params = state.params
        if not skip_local:
            params = blend(local_average(params, constraint_fn), do_local)
        params = jax.tree.map(
            lambda a, p: jnp.where(do_global, a, p),
            global_average(params, constraint_fn), params)
        return state._replace(params=params), metrics

    return step


# --------------------------------------------------------------------- #
# batch reshaping helpers
# --------------------------------------------------------------------- #

def round_batch_shape(hier: HierAvgParams, topo: HierTopology,
                      per_learner_batch: int) -> Tuple[int, ...]:
    return (hier.beta, hier.k1) + topo.shape + (per_learner_batch,)


def shard_round_batch(batch, hier: HierAvgParams, topo: HierTopology):
    """Reshape leaves [beta*K1*P*B, ...] -> [beta, K1, pods, G, S, B, ...]."""
    def rs(x):
        total = hier.beta * hier.k1 * topo.n_learners
        b = x.shape[0] // total
        return x.reshape((hier.beta, hier.k1) + topo.shape + (b,)
                         + x.shape[1:])
    return jax.tree.map(rs, batch)
