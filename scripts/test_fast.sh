#!/usr/bin/env bash
# Fast test tier + Pallas-interpret kernel checks — the pre-push gate.
#
#   scripts/test_fast.sh            # < 60s on CPU
#   scripts/test_fast.sh -k comm    # pass extra pytest args through
#
# The fast tier is the default pytest invocation (pyproject.toml deselects
# @pytest.mark.slow); the kernel suite re-runs explicitly so every Pallas
# kernel is validated against its XLA oracle (interpret mode, no TPU
# needed) even if parts of it are ever marked slow.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== fast tier =="
python -m pytest -x -q "$@"

echo "== pallas_interpret kernel checks =="
# the >2^24-row compaction test is minutes of interpret-mode compute on
# CPU — nightly's full suite covers it (pytest -m "")
python -m pytest -x -q -m "" tests/test_kernels.py \
    -k "not beyond_2e24"
