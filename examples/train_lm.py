"""End-to-end training driver: a ~100M-parameter decoder LM trained with
Hier-AVG for a few hundred steps on a Markov corpus, with eval + checkpoint.

CPU notes: the default --preset 25m finishes a few hundred steps in
minutes; --preset 100m is the full-size example (same code, ~4x slower per
step).  On TPU this exact script scales by swapping the Simulator topology
for the hier mesh shardings (launch/train.py path).

    PYTHONPATH=src python examples/train_lm.py --steps 64
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import HierAvgParams
from repro.configs.base import ArchConfig, ParallelLayout
from repro.core import HierTopology, Simulator, unstack_first
from repro.checkpoint import save_checkpoint
from repro.data.synthetic import make_markov_task, markov_lm_batch
from repro.models import build
from repro.models.common import count_params
from repro.optim import sgd, step_decay_lr

PRESETS = {
    # ~26M params
    "25m": dict(n_layers=4, d_model=384, n_heads=6, n_kv_heads=2,
                head_dim=64, d_ff=1152, vocab_size=4096),
    # ~101M params
    "100m": dict(n_layers=8, d_model=640, n_heads=10, n_kv_heads=2,
                 head_dim=64, d_ff=2048, vocab_size=8192),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="25m")
    ap.add_argument("--steps", type=int, default=256,
                    help="total local SGD steps (rounds = steps / k2)")
    ap.add_argument("--k1", type=int, default=2)
    ap.add_argument("--k2", type=int, default=8)
    ap.add_argument("--learners", type=int, default=4)
    ap.add_argument("--s", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--ckpt", default="/tmp/hier_avg_lm_ckpt")
    args = ap.parse_args()

    cfg = ArchConfig(name=f"lm-{args.preset}", family="dense",
                     source="examples/train_lm.py",
                     layout=ParallelLayout(1, 1, 1, 1),
                     **PRESETS[args.preset])
    bundle = build(cfg)
    n_params = count_params(bundle.init(jax.random.PRNGKey(0)))
    chain, floor = make_markov_task(cfg.vocab_size, temperature=1.8)

    def sample(key, n):
        return markov_lm_batch(key, n, args.seq, chain)

    topo = HierTopology(1, args.learners // args.s, args.s)
    hier = HierAvgParams(k1=args.k1, k2=args.k2)
    rounds = max(1, args.steps // hier.k2)
    lr = step_decay_lr(args.lr, [3 * args.steps // 4], [0.1])

    print(f"model: {n_params/1e6:.1f}M params | task entropy floor "
          f"{floor:.3f} nats | {topo.describe()} K1={hier.k1} K2={hier.k2}")
    sim = Simulator(bundle.loss_fn, bundle.init, sample, topo=topo,
                    hier=hier, optimizer=sgd(lr), per_learner_batch=args.batch,
                    eval_batch=sample(jax.random.PRNGKey(1), 32), seed=0)
    t0 = time.time()
    res = sim.run(rounds)
    dt = time.time() - t0
    toks = rounds * hier.k2 * topo.n_learners * args.batch * args.seq
    for r in range(0, rounds, max(1, rounds // 8)):
        print(f"round {r:4d}  train={res.losses[r]:.4f} "
              f"eval={res.eval_losses[r]:.4f}")
    print(f"final: train={res.losses[-1]:.4f} eval={res.eval_losses[-1]:.4f} "
          f"(floor {floor:.3f}) | {toks} tokens in {dt:.0f}s "
          f"({toks/dt:.0f} tok/s)")
    save_checkpoint(args.ckpt, unstack_first(res.state.params),
                    step=int(res.state.step),
                    metadata={"preset": args.preset})
    print(f"checkpoint -> {args.ckpt}")
    assert np.isfinite(res.eval_losses).all()
    assert res.eval_losses[-1] < res.eval_losses[0]


if __name__ == "__main__":
    main()
