"""Topology & communication demo: how each assigned architecture maps onto
the production pod, and what Hier-AVG saves versus K-AVG in reduction time.

    PYTHONPATH=src python examples/topology_demo.py
"""
from repro.configs import ALL_ARCHS, get_config
from repro.core import HierTopology
from repro.core.theory import CommModel, comm_per_k2_steps

print(f"{'arch':26s} {'params':>8s} {'layout G.S.F.TP':>16s} "
      f"{'learners/pod':>12s}  hier ms/step  kavg ms/step  saving")
cm = CommModel()
for arch in ALL_ARCHS:
    cfg = get_config(arch)
    lay = cfg.layout
    topo = HierTopology(2, lay.groups, lay.local)   # 2-pod view
    mb = cfg.param_count() * 2
    P, S = max(topo.n_learners, 2), max(lay.local, 2)
    loc, glo = comm_per_k2_steps(mb, 4, 8, P, S, cm)
    hier = (loc + glo) / 8 * 1e3
    _, glo_k = comm_per_k2_steps(mb, 4, 4, P, 1, cm)
    kavg = glo_k / 4 * 1e3
    print(f"{arch:26s} {cfg.param_count()/1e9:7.1f}B "
          f"{lay.groups}x{lay.local}x{lay.fsdp}x{lay.tp:>2d}      "
          f"{lay.learners_per_pod:>8d}     {hier:9.2f}    {kavg:9.2f}  "
          f"{1 - hier/kavg:6.1%}")

print("""
Communicator mapping (DESIGN.md §4):
  local reduction  = mean over the 'local' mesh axis   (intra-pod ICI)
  global reduction = mean over ('pod','group','local') (crosses DCI)
K-AVG at the same effective cadence pays the global (DCI) price every time;
Hier-AVG pays it once per K2 steps and rides ICI in between — the paper's
"trade local reductions for global reductions".""")
