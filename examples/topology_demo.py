"""Topology & communication demo: how each assigned architecture maps onto
the production pod, what Hier-AVG saves versus K-AVG in reduction time,
and the per-level payload/cost table of a 3-level ReductionPlan.

    PYTHONPATH=src python examples/topology_demo.py
"""
from repro.autotune.calibrate import resolve_comm_model
from repro.configs import ALL_ARCHS, get_config
from repro.core import HierTopology, ReductionPlan
from repro.core.theory import (CommModel, comm_per_k2_steps, param_template,
                               plan_comm_per_round)

print(f"{'arch':26s} {'params':>8s} {'layout G.S.F.TP':>16s} "
      f"{'learners/pod':>12s}  hier ms/step  kavg ms/step  saving")
# $REPRO_CALIBRATION (autotune/calibrate.py) swaps in measured constants
cal = resolve_comm_model()
cm = cal or CommModel()
if cal is not None:
    print(f"[calibrated comm model: fast_bw={cm.fast_bw:.3e} "
          f"slow_bw={cm.slow_bw:.3e} latency={cm.latency:.2e} "
          f"compress_bw={cm.compress_bw:.3e}]")
    if cm.codec_bw:
        print("[per-codec compress_bw: "
              + " ".join(f"{c}={bw:.3e}" for c, bw in cm.codec_bw) + "]")
for arch in ALL_ARCHS:
    cfg = get_config(arch)
    lay = cfg.layout
    topo = HierTopology(2, lay.groups, lay.local)   # 2-pod view
    mb = cfg.param_count() * 2
    P, S = max(topo.n_learners, 2), max(lay.local, 2)
    loc, glo = comm_per_k2_steps(mb, 4, 8, P, S, cm)
    hier = (loc + glo) / 8 * 1e3
    _, glo_k = comm_per_k2_steps(mb, 4, 4, P, 1, cm)
    kavg = glo_k / 4 * 1e3
    print(f"{arch:26s} {cfg.param_count()/1e9:7.1f}B "
          f"{lay.groups}x{lay.local}x{lay.fsdp}x{lay.tp:>2d}      "
          f"{lay.learners_per_pod:>8d}     {hier:9.2f}    {kavg:9.2f}  "
          f"{1 - hier/kavg:6.1%}")

print("""
Communicator mapping (DESIGN.md §4):
  local reduction  = mean over the 'local' mesh axis   (intra-pod ICI)
  global reduction = mean over ('pod','group','local') (crosses DCI)
K-AVG at the same effective cadence pays the global (DCI) price every time;
Hier-AVG pays it once per K2 steps and rides ICI in between — the paper's
"trade local reductions for global reductions".""")

# ------------------------------------------------------------------ #
# 3-level ReductionPlan: per-level payload / cost table
# ------------------------------------------------------------------ #

PLAN = "local@4:cast:bfloat16/pod@8:mean/global@16:topk:0.05"
from repro.comm import DEFAULT_BUCKET_BYTES
from repro.core.plan import apply_bucketing
plan = apply_bucketing(ReductionPlan.parse(PLAN), DEFAULT_BUCKET_BYTES)
print(f"\n3-level plan {plan.describe()} (2-pod view):\n")
print(f"{'arch':26s} {'level':7s} {'period':>6s} {'n':>4s} "
      f"{'payload MB':>10s} {'compress':>8s} {'x/round':>7s} "
      f"{'tier':>4s} {'msgs':>5s} {'codec':>6s} {'cdc ms':>7s} "
      f"{'ms/step':>8s} {'piped':>8s} {'overlap':>7s}")
for arch in ALL_ARCHS:
    cfg = get_config(arch)
    lay = cfg.layout
    topo = HierTopology(2, lay.groups, lay.local)
    dense = cfg.param_count() * 4          # fp32 mean baseline
    template = param_template(cfg.param_count(), dtype="float32",
                              n_leaves=max(1, 8 * cfg.n_layers))
    for lc in plan_comm_per_round(plan, topo, template, cm):
        tier = "dci" if lc.bandwidth == cm.slow_bw else "ici"
        print(f"{arch:26s} {lc.name:7s} {lc.period:>6d} "
              f"{lc.participants:>4d} {lc.payload_bytes / 2**20:>10.1f} "
              f"{dense / max(lc.payload_bytes, 1):>7.1f}x "
              f"{lc.count_per_round:>7d} {tier:>4s} {lc.messages:>5d} "
              f"{lc.codec or '-':>6s} "
              f"{lc.compute_s / plan.total_period * 1e3:>7.3f} "
              f"{lc.seconds_per_round / plan.total_period * 1e3:>8.3f} "
              f"{lc.overlap_s / plan.total_period * 1e3:>8.3f} "
              f"{lc.overlap_speedup:>6.2f}x")

print("""
Each level is costed over its own link tier (local/pod ride ICI, global
crosses DCI) and its own compressed payload (cast halves the words, topk
5% transmits value+index pairs for 5% of coordinates).  'codec'/'cdc ms'
are the level's codec family and its compress+reconstruct compute per
step, priced at CommModel.compress_bw_for(codec) — the per-codec rate
when a calibration artifact fitted one from codec-labeled probe points,
else the shared compress_bw constant.  'piped' is the
wall ms/step of the pipelined bucket schedule (comm/bucket.py Pipelined):
each bucket's collective overlaps the next bucket's compress, so a level
pays max(compute, comm) per stage plus the fill/drain ramp instead of the
sum — 'overlap' is the serial/pipelined wall ratio.  No legacy knob can
express this schedule — it is a ReductionPlan-only experiment.""")
