"""Quickstart: Hier-AVG in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Trains P=8 learners (2 clusters of S=4) on a Markov LM task with K1=2
local steps between local reductions and K2=4 between global ones.
"""
import jax

from repro.configs import HierAvgParams, get_config
from repro.core import HierTopology, Simulator
from repro.data.synthetic import make_markov_task, markov_lm_batch
from repro.models import build
from repro.optim import sgd

# 1. a model from the assigned pool (reduced so it runs on CPU)
cfg = get_config("rwkv6-1.6b").reduced()
bundle = build(cfg)

# 2. a data source — each learner will draw i.i.d. batches from it
chain, entropy_floor = make_markov_task(cfg.vocab_size, temperature=2.0)
sample = lambda key, n: markov_lm_batch(key, n, 32, chain)  # noqa: E731

# 3. the paper's knobs: P = pods*groups*local learners, S = local
topo = HierTopology(pods=1, groups=2, local=4)       # P=8, S=4
hier = HierAvgParams(k1=2, k2=4)                     # beta = 2

# 4. run rounds: K1 local SGD steps -> local average -> ... -> global average
sim = Simulator(bundle.loss_fn, bundle.init, sample, topo=topo, hier=hier,
                optimizer=sgd(0.5), per_learner_batch=4,
                eval_batch=sample(jax.random.PRNGKey(0), 64), seed=0)
result = sim.run(n_rounds=5)

print(f"topology: {topo.describe()}, K1={hier.k1}, K2={hier.k2}")
print(f"entropy floor of the task: {entropy_floor:.3f} nats")
for r, (tr, ev) in enumerate(zip(result.losses, result.eval_losses)):
    print(f"round {r}: train_loss={tr:.4f}  eval_loss={ev:.4f}")
assert result.eval_losses[-1] < result.eval_losses[0]
print("OK: loss decreased under hierarchical averaging.")
