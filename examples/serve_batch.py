"""Batched serving example: the Qwen2-VL backbone (reduced) answering a
queue of mixed-length requests through the slot-based engine, including the
vision-embedding stub path for one multimodal prefill.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.models.stubs import mrope_positions, vision_patch_embeds
from repro.serve import (GenerationConfig, PagedServeEngine, ServeEngine,
                         describe_cache)

cfg = get_config("qwen2-vl-2b").reduced()
bundle = build(cfg, cache_dtype=jnp.float32)
params = bundle.init(jax.random.PRNGKey(0))
engine = ServeEngine(bundle, params, max_len=96,
                     gen=GenerationConfig(max_new_tokens=8, temperature=0.7,
                                          seed=1))

# --- text request queue (mixed lengths, slot-batched) ---
rng = np.random.default_rng(0)
requests = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in (12, 12, 20, 20, 20, 8)]
t0 = time.time()
results = engine.serve_queue(requests, slots=2)
dt = time.time() - t0
print(f"served {len(results)} text requests in {dt:.1f}s")
for r in results[:3]:
    print(f"  req {r.request_id}: {len(r.prompt)} prompt toks -> "
          f"{r.tokens.tolist()}")

# --- one multimodal request: stub ViT patches + text, M-RoPE positions ---
nv, st = 16, 8
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, st)),
                     jnp.int32)
extras = {
    "vision_embeds": vision_patch_embeds(jax.random.PRNGKey(2), 1, nv,
                                         cfg.d_model),
    "positions": mrope_positions(1, nv, st),
}
out = engine.generate(tokens, extras=extras)
print(f"multimodal generate ({nv} patches + {st} text): {out[0].tolist()}")
print("decode cache:", describe_cache(cfg, batch=1, max_len=96))

# --- same queue through the paged engine: continuous batching means a
# finished request's slot (and its KV pages) is refilled mid-stream
# instead of waiting for its wave ---
paged = PagedServeEngine(bundle, params, slots=2, page_size=8, max_len=96,
                         prefill_chunk=8, cache_dtype=jnp.float32,
                         gen=GenerationConfig(max_new_tokens=8,
                                              temperature=0.7, seed=1))
t0 = time.time()
presults = paged.serve_queue(requests)
dt = time.time() - t0
print(f"paged: served {len(presults)} requests in {dt:.1f}s "
      f"(pool {paged.alloc.n_pages - 1} pages, "
      f"peak {paged.alloc.peak_in_use} in use, "
      f"{paged.prefill_traces}+{paged.decode_traces} compiles)")
for r in presults[:3]:
    print(f"  req {r.request_id}: {len(r.prompt)} prompt toks -> "
          f"{r.tokens.tolist()} in {r.decode_steps} decode steps")
